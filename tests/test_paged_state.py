"""Paged ternary state: block pool / prefix cache / paged LLM serving.

Three layers of coverage:

* allocator mechanics — refcounts, LRU parking + eviction, COW,
  the reserved null block, prefix-cache chain matching;
* physical stores — 5-trits/byte pack/unpack exactness, KV
  gather/scatter through block tables, null-block padding routing;
* the paged `LLMExecutor` — **bit-exactness against the contiguous
  baseline** across dense / moe / mamba2, prefix hits surviving forks
  and evictions, the validate() length budget, and the engine-stats
  plumbing.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as TF
from repro.models.config import reduce_for_smoke
from repro.serving import CutieEngine, LLMExecutor, ServerConfig
from repro.serving.blocks import (NULL_BLOCK, BlockPool, KVPagedStore,
                                  OutOfBlocks, PagedSequenceManager,
                                  PrefixCache, StatePagedStore,
                                  chain_hashes, pack_last_axis,
                                  unpack_last_axis)

# ---------------------------------------------------------------------------
# BlockPool: allocate / retain / release / evict / COW
# ---------------------------------------------------------------------------


def test_pool_lifecycle_and_null_block():
    pool = BlockPool(5)
    assert pool.capacity == 4 and pool.n_free == 4
    a, b = pool.allocate(), pool.allocate()
    assert NULL_BLOCK not in (a, b)
    assert pool.n_active == 2
    with pytest.raises(ValueError):
        pool.retain(NULL_BLOCK)
    pool.release(a)
    assert pool.n_free == 3                  # anonymous block -> free list
    with pytest.raises(ValueError):
        pool.release(a)                      # double release


def test_pool_parks_hashed_blocks_and_evicts_lru():
    dropped = []
    pool = BlockPool(4, on_evict=lambda bid, h: dropped.append((bid, h)))
    x, y, z = pool.allocate(), pool.allocate(), pool.allocate()
    pool.set_hash(x, "hx")
    pool.set_hash(y, "hy")
    pool.release(x)                          # parks (LRU-oldest)
    pool.release(y)                          # parks
    pool.release(z)                          # anonymous -> free
    assert pool.n_cached == 2 and pool.n_free == 1
    got = [pool.allocate(), pool.allocate()]  # free first, then evict x
    assert pool.evictions == 1 and dropped == [(x, "hx")]
    assert x in got
    # everything referenced now -> exhausted
    pool.allocate()                          # evicts y
    with pytest.raises(OutOfBlocks):
        pool.allocate()


def test_pool_retain_reactivates_parked_block():
    pool = BlockPool(3)
    a = pool.allocate()
    pool.set_hash(a, "h")
    pool.release(a)
    assert pool.n_cached == 1
    pool.retain(a)                           # prefix hit on a parked block
    assert pool.n_cached == 0 and pool.refcount(a) == 1


def test_pool_copy_on_write():
    pool = BlockPool(5)
    a = pool.allocate()
    assert pool.writable(a) == (a, None)     # exclusive: in-place ok
    pool.retain(a)                           # now shared (ref 2)
    new, pair = pool.writable(a)
    assert new != a and pair == (a, new)
    assert pool.refcount(a) == 1 and pool.refcount(new) == 1
    # hash-registered blocks are shared even at refcount 1
    b = pool.allocate()
    pool.set_hash(b, "hb")
    nb, pairb = pool.writable(b)
    assert nb != b and pairb == (b, nb)


def test_pool_stale_retain_raises():
    """Regression: retaining a freed (or evicted-and-recycled) block id
    silently corrupted the free list — a stale id resurrected into two
    owners.  It must raise instead."""
    pool = BlockPool(4)
    a = pool.allocate()
    pool.release(a)                          # anonymous -> free list
    with pytest.raises(ValueError, match="stale"):
        pool.retain(a)
    # a parked block is NOT stale: prefix hits retain it legitimately
    b = pool.allocate()
    pool.set_hash(b, "hb")
    pool.release(b)                          # parks (LRU)
    pool.retain(b)
    assert pool.refcount(b) == 1


# ---------------------------------------------------------------------------
# PrefixCache: chain hashing + matching
# ---------------------------------------------------------------------------


def test_chain_hash_is_positional_through_chaining():
    toks = np.arange(8)
    h1 = chain_hashes(toks, 4)
    # same second block, different first block -> different chain key
    other = np.concatenate([np.arange(4) + 50, np.arange(4, 8)])
    h2 = chain_hashes(other, 4)
    assert h1[1] != h2[1]
    assert h1 == chain_hashes(toks, 4)       # deterministic


def test_prefix_cache_match_clamp_and_hit_rate():
    cache = PrefixCache()
    toks = np.arange(12)
    hs = chain_hashes(toks, 4)
    for i, h in enumerate(hs):
        cache.insert(h, i + 1)
    hs_m, bids = cache.match(toks, 4, max_blocks=2)   # clamped
    assert bids == [1, 2] and hs_m == hs[:2]
    assert cache.hit_rate == 8 / 12
    # drop only removes the mapping it still owns
    cache.drop(99, hs[0])                    # stale bid: no-op
    assert cache.get(hs[0]) == 1
    cache.drop(1, hs[0])
    assert cache.get(hs[0]) is None


# ---------------------------------------------------------------------------
# PagedSequenceManager: tables, sharing, COW, fork
# ---------------------------------------------------------------------------


def _mgr(num_blocks=12, bs=4):
    pool = BlockPool(num_blocks)
    cache = PrefixCache()
    pool.on_evict = cache.drop
    return PagedSequenceManager(pool, cache, bs)


def test_manager_prefix_reuse_shares_physical_blocks():
    m = _mgr()
    toks = np.arange(10)
    s1 = m.create(1, toks, total_len=12)
    assert s1.n_cached == 0
    m.commit(1)
    s2 = m.create(2, toks, total_len=12)
    assert s2.n_cached == 8                  # 2 full blocks reused
    assert s2.table[:2] == s1.table[:2]      # same physical blocks
    assert s2.table[2] != s1.table[2]        # private tail
    # last prompt token always recomputed: exact-multiple prompt
    s3 = m.create(3, np.arange(8), total_len=12)
    assert s3.n_cached == 4                  # clamped below 8


def test_manager_commit_is_insert_if_absent():
    m = _mgr()
    toks = np.arange(10)
    m.create(1, toks, 12)
    m.commit(1)
    m.create(2, toks, 12)
    m.commit(2)                              # duplicate chain: no steal
    hs = chain_hashes(toks, 4)
    assert m.cache.get(hs[0]) == m.get(1).table[0]
    assert m.get(2).table[0] == m.get(1).table[0]


def test_manager_fork_cow_and_free():
    m = _mgr()
    toks = np.arange(10)
    m.create(1, toks, 12)
    m.commit(1)
    m.fork(1, 2)
    assert m.get(2).table == m.get(1).table
    pair = m.ensure_writable(2, 9)           # child writes pos 9 (block 2)
    assert pair is not None
    assert m.get(2).table[2] != m.get(1).table[2]
    # parent's block 2 is exclusive again -> in-place
    assert m.ensure_writable(1, 9) is None
    m.free(2)
    m.free(1)
    # committed blocks park, private blocks free
    assert m.pool.n_active == 0 and m.pool.n_cached == 2


def test_manager_eviction_invalidates_prefix_then_recovers():
    m = _mgr(num_blocks=7, bs=4)             # capacity 6
    toks = np.arange(10)
    m.create(1, toks, 12)
    m.commit(1)
    m.free(1)                                # 2 parked + 4 free
    # pressure: a novel sequence needing 5 blocks evicts the parked LRU
    m.create(2, np.arange(18) + 90, 20)
    assert m.pool.evictions >= 1
    m.free(2)
    # original prompt now misses (chain broken at the evicted block)
    s3 = m.create(3, toks, 12)
    assert s3.n_cached < 8
    m.commit(3)
    m.free(3)
    s4 = m.create(4, toks, 12)               # recommitted -> hits again
    assert s4.n_cached == 8


def test_manager_probe_false_skips_cache():
    m = _mgr()
    toks = np.arange(10)
    m.create(1, toks, 12)
    m.commit(1)
    s = m.create(2, toks, 12, probe=False)
    assert s.n_cached == 0
    assert m.cache.lookup_tokens == 10       # only seq 1's probe counted


def test_manager_rid_collision_raises():
    """Regression: create()/fork() onto a live rid silently overwrote
    its record, orphaning the old table's refcounts forever (and a
    later free() double-released whichever record survived)."""
    m = _mgr()
    toks = np.arange(10)
    m.create(1, toks, 12)
    with pytest.raises(ValueError, match="already exists"):
        m.create(1, toks, 12)
    with pytest.raises(ValueError, match="already exists"):
        m.fork(1, 1)
    m.create(2, toks, 12)
    with pytest.raises(ValueError, match="live sequence"):
        m.adopt(2, 1)
    assert m.pool.n_active == 6              # nothing leaked by the raises
    m.free(1)
    m.free(2)
    assert m.pool.n_active == 0


def test_fork_commit_adopt_under_eviction_pressure():
    """The speculative write path's fork-commit protocol interleaved
    with eviction: fork a shadow of a committed sequence, COW its write
    span while the pool is tight enough that parked prefix blocks get
    recycled mid-flight, then free-the-original + adopt.  No block may
    be double-released or resurrected, and rollback (freeing the shadow
    instead) must leave the original untouched."""
    m = _mgr(num_blocks=8, bs=4)             # capacity 7
    toks = np.arange(10)
    m.create(1, toks, 12)                    # 3 blocks
    m.commit(1)                              # 2 hash-registered
    # park an unrelated committed prefix so eviction has a victim
    m.create(9, np.arange(8) + 70, 8)
    m.commit(9)
    m.free(9)                                # 2 parked, 2 free
    assert m.pool.n_cached == 2

    # shadow fork + span COW: needs 3 fresh blocks (every forked block
    # is shared) -> the free list runs dry and a parked block is
    # evicted and recycled as a COW destination mid-protocol
    m.fork(1, -1)
    pairs = m.ensure_span_writable(-1, 0, 10)
    assert len(pairs) == 3 and m.pool.evictions >= 1
    for src, dst in pairs:
        assert src != dst
    # commit: free the original, adopt the shadow under its id
    m.free(1)
    m.adopt(-1, 1)
    assert m.has(1) and not m.has(-1)
    # every table entry is exclusively owned and alive
    for bid in m.get(1).table:
        assert m.pool.refcount(bid) == 1
    m.free(1)
    assert m.pool.n_active == 0

    # rollback leg: fork a shadow, COW, then free the *shadow* — the
    # original must still decode (all blocks alive, refcount 1)
    m2 = _mgr(num_blocks=9, bs=4)
    m2.create(1, toks, 12)
    m2.commit(1)
    m2.fork(1, -1)
    m2.ensure_span_writable(-1, 0, 10)
    m2.free(-1)
    for bid in m2.get(1).table:
        assert m2.pool.refcount(bid) == 1
    m2.free(1)
    assert m2.pool.n_active == 0


# ---------------------------------------------------------------------------
# stores: trit packing + gather/scatter
# ---------------------------------------------------------------------------


def test_trit_pack_roundtrip_exact_and_5x():
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.integers(-1, 2, size=(6, 37)), jnp.int8)
    packed = pack_last_axis(t)
    assert packed.shape == (6, 8)            # ceil(37/5): 5 trits/byte
    assert (unpack_last_axis(packed, 37) == t).all()


def test_kv_store_gather_scatter_roundtrip():
    st = KVPagedStore(2, 6, 4, 2, 8, dtype="bfloat16")
    tables = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    rng = np.random.default_rng(1)
    rows = {n: jnp.asarray(rng.normal(size=(2, 2, 2, 8)), jnp.bfloat16)
            for n in ("k", "v")}
    st.pages = st.write_rows(st.pages, tables, jnp.asarray([3, 6]), rows)
    g = st.gather(st.pages, tables)
    assert g["k"].shape == (2, 2, 8, 2, 8)
    assert (g["k"][:, 0, 3] == rows["k"][:, 0]).all()
    assert (g["v"][:, 1, 6] == rows["v"][:, 1]).all()


def test_kv_store_write_span_routes_padding_to_null_block():
    st = KVPagedStore(1, 4, 4, 1, 4)
    table = jnp.asarray([1, 2], jnp.int32)
    kv = {n: jnp.ones((1, 8, 1, 4), jnp.bfloat16) for n in ("k", "v")}
    # start=2, only 3 real rows; 5 padded rows must not land in blocks
    st.pages = st.write_span(st.pages, table, jnp.int32(2), jnp.int32(3),
                             kv)
    g = st.gather(st.pages, table[None])
    real = np.asarray(g["k"][0, 0, :, 0, 0])
    assert (real[2:5] == 1.0).all()
    assert (real[:2] == 0).all() and (real[5:] == 0).all()


def test_state_store_trit_snapshots_are_exact():
    rng = np.random.default_rng(2)
    template = {"a": jnp.zeros((2, 9), jnp.int8),
                "b": jnp.zeros((5,), jnp.int8)}
    st = StatePagedStore(4, template, codec_name="trit")
    state = {"a": jnp.asarray(rng.integers(-1, 2, (2, 9)), jnp.int8),
             "b": jnp.asarray(rng.integers(-1, 2, (5,)), jnp.int8)}
    st.write_(2, state)
    back = st.read_([2])
    assert (back["a"][0] == state["a"]).all()
    assert (back["b"][0] == state["b"]).all()
    # packed block is ~5x smaller than int8
    assert st.pages[0].shape[-1] == -(-18 // 5)


# ---------------------------------------------------------------------------
# LLMExecutor: paged == contiguous, end to end
# ---------------------------------------------------------------------------

_SHARED = list(np.arange(20) % 50)
_PROMPTS = [np.array(_SHARED + [100 + i, i]) for i in range(4)]


def _model(name, layers):
    cfg = reduce_for_smoke(configs.get(name)).replace(n_layers=layers)
    return TF.init_params(cfg, jax.random.PRNGKey(0)), cfg


def _serve(params, cfg, scfg, prompts=_PROMPTS):
    eng = CutieEngine("fcfs")
    ex = LLMExecutor(params, cfg, scfg)
    eng.register("llm", ex)
    for pr in prompts:
        eng.submit(pr, model="llm")
    return eng.run(), ex, eng


@pytest.mark.parametrize("name,layers", [
    ("llama3_2_1b", 1), ("deepseek_moe_16b", 2), ("mamba2_780m", 1)])
def test_paged_bit_identical_to_contiguous(name, layers):
    params, cfg = _model(name, layers)
    kw = dict(n_slots=2, max_new_tokens=4, max_len=64, block_size=8)
    out_c, _, _ = _serve(params, cfg, ServerConfig(paged=False, **kw))
    out_p, ex, eng = _serve(params, cfg, ServerConfig(paged=True, **kw))
    assert out_c == out_p                    # token-for-token identical
    st = ex.extra_stats()
    assert st["prefix_hit_rate"] > 0.5       # shared-prefix trace
    assert st["prefill_tokens_computed"] < st["prefill_tokens"]
    # stats ride into engine.stats()
    es = eng.stats()["paged_state"]["llm"]
    assert es["prefix_hit_rate"] == st["prefix_hit_rate"]
    assert es["evictions"] == 0 and "block_occupancy" in es


def test_paged_correct_under_eviction_pressure():
    """A pool too small to retain every prefix must evict parked blocks,
    recycle them, and still produce the contiguous answer."""
    params, cfg = _model("llama3_2_1b", 1)
    kw = dict(n_slots=2, max_new_tokens=4, max_len=64, block_size=8)
    # distinct prefixes: every finished prompt parks 2 committed blocks,
    # so a 9-block pool (4 per live seq) runs dry by the 4th admission
    prompts = [np.concatenate([[i], np.arange(21) % 40])
               for i in range(4)]
    tight = ServerConfig(paged=True, num_blocks=10, **kw)
    out_c, _, _ = _serve(params, cfg, ServerConfig(paged=False, **kw),
                         prompts)
    out_p, ex, _ = _serve(params, cfg, tight, prompts)
    assert out_c == out_p
    assert ex.extra_stats()["evictions"] > 0


class _Req:
    def __init__(self, uid, value):
        self.uid, self.value = uid, value


def test_fork_is_copy_on_write_and_does_not_perturb_parent():
    params, cfg = _model("llama3_2_1b", 1)
    scfg = ServerConfig(paged=True, n_slots=2, max_new_tokens=6,
                        max_len=64, block_size=8)
    prompt = np.asarray(_PROMPTS[0], np.int32)

    def drain(ex, reqs=()):
        outs = {}
        rep = ex.execute(list(reqs))
        for uid, toks in rep.completions:
            outs[uid] = toks
        for _ in range(40):
            if not ex.has_resident():
                break
            for uid, toks in ex.execute([]).completions:
                outs[uid] = toks
        return outs

    base = drain(LLMExecutor(params, cfg, scfg), [_Req(1, prompt)])

    ex = LLMExecutor(params, cfg, scfg)
    ex.execute([_Req(1, prompt)])            # prefill + first decode
    ex.fork(1, 2)
    # the child shares every physical block until someone writes
    assert ex.manager.get(2).table == ex.manager.get(1).table
    outs = drain(ex)
    assert outs[1] == base[1]                # parent bit-identical
    assert outs[2] == base[1]                # greedy child follows suit
    assert ex.pool.n_active == 0             # both released on completion


def test_validate_rejects_prompt_plus_budget_overflow():
    params, cfg = _model("llama3_2_1b", 1)
    scfg = ServerConfig(n_slots=1, max_len=32, max_new_tokens=8,
                        block_size=8)
    ex = LLMExecutor(params, cfg, scfg)
    ex.validate(np.arange(24))               # 24 + 8 == 32: fits
    with pytest.raises(ValueError, match="max_new_tokens"):
        ex.validate(np.arange(25))           # 25 + 8 > 32
    with pytest.raises(ValueError, match="non-empty"):
        ex.validate(np.zeros((0,), np.int32))


def test_free_capacity_is_block_limited():
    params, cfg = _model("llama3_2_1b", 1)
    scfg = ServerConfig(paged=True, n_slots=4, max_len=64, block_size=8,
                        max_new_tokens=4, num_blocks=1 + 2 * 8)
    ex = LLMExecutor(params, cfg, scfg)
    assert ex.free_capacity() == 2           # 16 blocks / 8 per seq


def test_config_rejects_misaligned_block_size():
    params, cfg = _model("llama3_2_1b", 1)
    with pytest.raises(ValueError, match="multiple"):
        LLMExecutor(params, cfg, ServerConfig(max_len=60, block_size=8))


# ---------------------------------------------------------------------------
# satellite: pipeline execution plan + fused-on-mesh warning
# ---------------------------------------------------------------------------


def _cnn_program(c=8, depth=2, seed=0):
    from repro.core import engine as core_engine

    keys = jax.random.split(jax.random.PRNGKey(seed), depth)
    instrs = []
    for k in keys:
        k1, k2 = jax.random.split(k)
        w = jax.random.normal(k1, (3, 3, c, c))
        bn = {"gamma": jax.random.normal(k2, (c,)) + 0.5,
              "beta": jnp.zeros((c,)), "mean": jnp.zeros((c,)),
              "var": jnp.ones((c,))}
        instrs.append(core_engine.compile_layer(w, bn))
    return core_engine.CutieProgram(
        instrs, core_engine.CutieInstance(n_i=c, n_o=c))


def test_execution_plan_modes():
    from repro.pipeline import CutiePipeline

    prog = _cnn_program()
    assert CutiePipeline(prog, backend="ref").execution_plan()["mode"] \
        == "scan"
    plan = CutiePipeline(prog, backend="fused").execution_plan()
    assert plan["mode"] == "program" and plan["backend"] == "fused"


def test_fused_backend_on_mesh_warns_and_reports_per_layer():
    from repro.pipeline import CutiePipeline

    prog = _cnn_program(seed=3)
    with pytest.warns(UserWarning, match="per-layer"):
        pipe = CutiePipeline(prog, backend="fused", mesh=1)
    plan = pipe.execution_plan()
    assert plan["mode"] == "sharded-per-layer"
    assert "dropped" in plan["reason"]
    # non-program backends shard without complaint
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pipe2 = CutiePipeline(prog, backend="ref", mesh=1)
    assert pipe2.execution_plan()["mode"] == "sharded-per-layer"
