import os
import sys

import numpy as np
import pytest

# Multi-device tests need forced XLA host devices, and the flag only
# takes effect if it is set before jax first initializes its backend.
# conftest is imported before any test module, so one session-wide
# setting here replaces the per-file subprocess/env hacks; the
# `host_devices` fixture verifies the topology actually stuck and skips
# with a clear reason when it could not be applied (e.g. jax was already
# initialized by the embedding process or a plugin).
HOST_DEVICE_COUNT = 8
_FLAG = f"--xla_force_host_platform_device_count={HOST_DEVICE_COUNT}"

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = f"{_flags} {_FLAG}".strip()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def host_devices() -> int:
    """Forced host-device count, for multi-device tests.

    Skips — rather than failing on a 1-device mesh error — when the
    forced topology could not be applied to this process.
    """
    import jax

    n = jax.device_count()
    if n < HOST_DEVICE_COUNT:
        pytest.skip(
            f"needs {HOST_DEVICE_COUNT} host devices but jax sees {n}: "
            f"jax was initialized before conftest could apply "
            f"XLA_FLAGS {_FLAG!r} (run under plain pytest)")
    return HOST_DEVICE_COUNT
