"""Fused-trunk execution: megakernel, shared epilogue, segmentation.

The acceptance property of the ``fused`` backend: a contiguous trunk of
uniform layers running inside ONE Pallas megakernel — weights stationary
in VMEM, activations ping-ponging between scratch buffers, pooling /
thresholds / degenerate channels resolved in-register — is bit-identical
to the ``ref`` oracle, and so are the per-layer kernels it falls back to
at trunk boundaries (including the packed-decode-in-kernel conv).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.core import codec, engine
from repro.kernels import fused_trunk as FT
from repro.kernels import ternary_conv2d as K
from repro.pipeline import CutiePipeline, FusedBackend, StatsTracer


def _layer(key, cin, cout, *, pool=None, stride=(1, 1), padding=True,
           const_frac=0.0):
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (3, 3, cin, cout))
    gamma = jax.random.normal(k2, (cout,)) + 0.5
    if const_frac:
        gamma = jnp.where(jax.random.bernoulli(k3, const_frac, (cout,)),
                          0.0, gamma)
    bn = {"gamma": gamma, "beta": jnp.zeros((cout,)),
          "mean": jnp.zeros((cout,)), "var": jnp.ones((cout,))}
    return engine.compile_layer(w, bn, pool=pool, stride=stride,
                                padding=padding)


def _trits(key, shape):
    return jax.random.randint(key, shape, -1, 2).astype(jnp.int8)


def _stack_thresholds(layers):
    return [jnp.stack([getattr(li.thresholds, f) for li in layers])
            for f in ("t_lo", "t_hi", "flip", "const", "is_const")]


def _oracle(layers, x):
    cur = x
    for li in layers:
        cur, _ = engine.run_layer(cur, li)
    return np.asarray(cur)


# ---------------------------------------------------------------------------
# per-layer kernel: pool x stride x fused threshold epilogue vs ref oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pool", [None, ("max", 2), ("avg", 2), ("max", 3)])
@pytest.mark.parametrize("stride", [(1, 1), (2, 2)])
@pytest.mark.parametrize("padding", [True, False])
def test_conv_kernel_full_epilogue_matches_ref(pool, stride, padding):
    instr = _layer(jax.random.PRNGKey(hash((pool, stride, padding)) % 1000),
                   8, 16, pool=pool, stride=stride, padding=padding,
                   const_frac=0.25)
    x = _trits(jax.random.PRNGKey(1), (2, 13, 13, 8))
    want, _ = engine.run_layer(x, instr)
    th = instr.thresholds
    got = K.ternary_conv2d_pallas(
        x, instr.weights, stride=stride, padding=padding,
        t_lo=th.t_lo, t_hi=th.t_hi, flip=th.flip, const=th.const,
        is_const=th.is_const, pool=pool, interpret=True)
    assert got.dtype == jnp.int8
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_conv_kernel_degenerate_pool_geometry_raises_clearly():
    """Pool window larger than the conv output: a named error at trace
    time, not a negative-limit lax.slice TypeError from inside the
    kernel."""
    instr = _layer(jax.random.PRNGKey(8), 8, 8, pool=("avg", 4))
    x = _trits(jax.random.PRNGKey(9), (1, 2, 2, 8))
    th = instr.thresholds
    with pytest.raises(ValueError, match="pool window 4 exceeds"):
        K.ternary_conv2d_pallas(
            x, instr.weights, t_lo=th.t_lo, t_hi=th.t_hi, flip=th.flip,
            const=th.const, is_const=th.is_const, pool=("avg", 4),
            interpret=True)


def test_conv_kernel_pool_requires_thresholds():
    instr = _layer(jax.random.PRNGKey(0), 8, 8, pool=("max", 2))
    x = _trits(jax.random.PRNGKey(1), (1, 8, 8, 8))
    with pytest.raises(ValueError, match="pooling requires"):
        K.ternary_conv2d_pallas(x, instr.weights, pool=("max", 2),
                                interpret=True)


def test_conv_kernel_legacy_three_vector_epilogue_still_works():
    """Callers without const/is_const (kernels/ops.py) keep old semantics."""
    instr = _layer(jax.random.PRNGKey(3), 8, 8)
    x = _trits(jax.random.PRNGKey(4), (1, 8, 8, 8))
    th = instr.thresholds
    got = K.ternary_conv2d_pallas(x, instr.weights, t_lo=th.t_lo,
                                  t_hi=th.t_hi, flip=th.flip,
                                  interpret=True)
    want, _ = engine.run_layer(x, instr)
    assert np.array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# degenerate (g == 0) channels resolve inside the kernels (regression:
# the fixup used to be a post-kernel jnp.where on the pallas backend only)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "packed", "fused"])
@pytest.mark.parametrize("pool", [None, ("max", 2)])
def test_constant_channels_fixed_up_in_kernel(backend, pool):
    layers = [_layer(k, 8, 8, pool=pool, const_frac=0.5)
              for k in jax.random.split(jax.random.PRNGKey(5), 3)]
    assert any(bool(np.asarray(li.thresholds.is_const).any())
               for li in layers)
    prog = engine.CutieProgram(layers, engine.CutieInstance(n_i=8, n_o=8))
    x = _trits(jax.random.PRNGKey(6), (2, 8, 8, 8))
    want = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    got = np.asarray(CutiePipeline(prog, backend=backend).run(x))
    assert np.array_equal(want, got)


# ---------------------------------------------------------------------------
# packed-decode-in-kernel bit-exactness across channel counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cin,cout", [(5, 13), (8, 8), (13, 7), (16, 24),
                                      (20, 40)])
def test_packed_decode_in_kernel_matches_ref(cin, cout):
    """Channel counts the compiler's pad_to/DCE can emit: K*K*Cin rarely
    a multiple of 5, Cout not a power of two."""
    instr = _layer(jax.random.PRNGKey(cin * 100 + cout), cin, cout,
                   const_frac=0.2)
    x = _trits(jax.random.PRNGKey(2), (2, 9, 9, cin))
    want, _ = engine.run_layer(x, instr)
    th = instr.thresholds
    wp = codec.pack_filter_rows(instr.weights)
    assert wp.shape == (cout, -(-3 * 3 * cin // 5))
    got = K.ternary_conv2d_packed_pallas(
        x, wp, k=3, cin=cin, t_lo=th.t_lo, t_hi=th.t_hi, flip=th.flip,
        const=th.const, is_const=th.is_const, interpret=True)
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_packed_backend_on_pad_to_compiled_program():
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    g = compiler.Graph(in_channels=5, in_hw=(8, 8))
    g.conv(jax.random.normal(ks[0], (3, 3, 5, 13)),
           {"gamma": jax.random.normal(ks[2], (13,)) + 0.5})
    g.conv(jax.random.normal(ks[1], (3, 3, 13, 7)),
           {"gamma": jax.random.normal(ks[3], (7,)) + 0.5})
    x = _trits(ks[0], (1, 8, 8, 5))
    for pad_to in (None, 16):
        res = compiler.compile_graph(g, optimize=False, pad_to=pad_to)
        want = np.asarray(CutiePipeline(res.program, backend="ref").run(x))
        got = np.asarray(
            CutiePipeline(res.program, backend="packed").run(x))
        assert np.array_equal(want, got), pad_to


# ---------------------------------------------------------------------------
# the trunk megakernel
# ---------------------------------------------------------------------------


def test_trunk_kernel_uniform_layers_matches_oracle():
    layers = [_layer(k, 8, 8, const_frac=0.2)
              for k in jax.random.split(jax.random.PRNGKey(11), 5)]
    x = _trits(jax.random.PRNGKey(12), (3, 10, 10, 8))
    got = FT.fused_trunk_pallas(
        x, jnp.stack([li.weights for li in layers]),
        *_stack_thresholds(layers),
        metas=tuple((li.stride, li.pool) for li in layers), interpret=True)
    assert np.array_equal(_oracle(layers, x), np.asarray(got))


@pytest.mark.parametrize("pools,strides", [
    ([None, ("max", 2), None, ("avg", 2)],
     [(1, 1), (1, 1), (1, 1), (1, 1)]),
    ([None, None, ("max", 2)], [(2, 2), (1, 1), (1, 1)]),
    ([("avg", 4)], [(1, 1)]),
])
def test_trunk_kernel_pool_and_stride_inside_trunk(pools, strides):
    keys = jax.random.split(jax.random.PRNGKey(13), len(pools))
    layers = [_layer(k, 8, 8, pool=p, stride=s, const_frac=0.2)
              for k, p, s in zip(keys, pools, strides)]
    x = _trits(jax.random.PRNGKey(14), (2, 16, 16, 8))
    got = FT.fused_trunk_pallas(
        x, jnp.stack([li.weights for li in layers]),
        *_stack_thresholds(layers),
        metas=tuple((li.stride, li.pool) for li in layers), interpret=True)
    assert np.array_equal(_oracle(layers, x), np.asarray(got))


def test_trunk_shapes_static_inference():
    metas = (((1, 1), None), ((1, 1), ("max", 2)), ((2, 2), None))
    assert FT.trunk_shapes((16, 16), 3, metas) == [
        (16, 16), (16, 16), (8, 8), (4, 4)]


# ---------------------------------------------------------------------------
# trunk segmentation (compiler pass)
# ---------------------------------------------------------------------------


def _uniform(c, depth, seed=0, **kw):
    keys = jax.random.split(jax.random.PRNGKey(seed), depth)
    return [_layer(k, c, c, **kw) for k in keys]


def _instance(c=16):
    return engine.CutieInstance(n_i=c, n_o=c)


def test_segmentation_uniform_program_is_one_trunk():
    prog = engine.CutieProgram(_uniform(8, 4), _instance(8))
    segs = compiler.plan_segments(prog, (2, 8, 8, 8))
    assert segs == [compiler.Trunk(0, 4, fused=True,
                                   vmem_bytes=segs[0].vmem_bytes)]
    assert segs[0].vmem_bytes == compiler.trunk_vmem_bytes(
        prog.layers, (2, 8, 8, 8))


def test_segmentation_breaks_on_width_change_but_heads_may_widen():
    """A trunk head's Cin may differ (zero-padded in); width changes
    mid-run start a new trunk instead."""
    ks = jax.random.split(jax.random.PRNGKey(21), 6)
    layers = (
        [_layer(ks[0], 6, 8)]            # Cin != Cout -> heads trunk 1
        + [_layer(k, 8, 8) for k in ks[1:3]]
        + [_layer(ks[3], 8, 16)]         # width change -> heads trunk 2
        + [_layer(k, 16, 16) for k in ks[4:6]])
    prog = engine.CutieProgram(layers, _instance())
    segs = compiler.plan_segments(prog, (1, 12, 12, 6))
    assert [(s.start, s.stop, s.fused) for s in segs] == [
        (0, 3, True), (3, 6, True)]


def test_segmentation_unpadded_layer_breaks_trunk():
    layers = _uniform(8, 2, seed=22) + \
        [_layer(jax.random.PRNGKey(23), 8, 8, padding=False)] + \
        _uniform(8, 2, seed=24)
    prog = engine.CutieProgram(layers, _instance(8))
    segs = compiler.plan_segments(prog, (1, 12, 12, 8))
    assert [(s.start, s.stop, s.fused) for s in segs] == [
        (0, 2, True), (2, 3, False), (3, 5, True)]


def test_segmentation_vmem_budget_splits_trunk():
    prog = engine.CutieProgram(_uniform(8, 6, seed=25), _instance(8))
    in_shape = (1, 8, 8, 8)
    full = compiler.plan_segments(prog, in_shape)
    assert [s.fused for s in full] == [True]
    # budget that fits ~2 layers of weights + the fixed activation cost
    fixed = compiler.trunk_vmem_bytes(prog.layers[:1], in_shape) \
        - int(prog.layers[0].weights.size)
    budget = fixed + 2 * int(prog.layers[0].weights.size) + 100
    segs = compiler.plan_segments(prog, in_shape, budget)
    assert len(segs) > 1
    assert all(s.fused for s in segs if len(s) >= 2)
    assert [s.start for s in segs] + [segs[-1].stop] == sorted(
        set([s.start for s in segs] + [s.stop for s in segs]))
    # still covers every layer exactly once, in order
    cover = [i for s in segs for i in range(s.start, s.stop)]
    assert cover == list(range(len(prog.layers)))


def test_segmentation_lone_layers_stay_per_layer_and_group():
    """No two consecutive layers share a width: nothing trunks, and the
    whole run collapses into ONE per-layer segment (fewest boundaries)."""
    ks = jax.random.split(jax.random.PRNGKey(26), 3)
    layers = [_layer(ks[0], 6, 8), _layer(ks[1], 8, 16),
              _layer(ks[2], 16, 6)]
    prog = engine.CutieProgram(layers, _instance())
    segs = compiler.plan_segments(prog, (1, 8, 8, 6))
    assert [(s.start, s.stop, s.fused) for s in segs] == [(0, 3, False)]


def test_segmentation_widening_head_plus_tail():
    """Head widens into the trunk; the width-changing tail falls back."""
    ks = jax.random.split(jax.random.PRNGKey(27), 3)
    layers = [_layer(ks[0], 6, 8), _layer(ks[1], 8, 8),
              _layer(ks[2], 8, 6)]
    prog = engine.CutieProgram(layers, _instance())
    segs = compiler.plan_segments(prog, (1, 8, 8, 6))
    assert [(s.start, s.stop, s.fused) for s in segs] == [
        (0, 2, True), (2, 3, False)]


# ---------------------------------------------------------------------------
# the fused backend end-to-end
# ---------------------------------------------------------------------------


def _cifar_like_program(seed=31, c=16, cin=10):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    pools = [None, None, ("max", 2), None, ("max", 2), None, ("max", 2),
             ("avg", 4)]
    layers = [_layer(ks[0], cin, c, pool=pools[0], const_frac=0.1)]
    layers += [_layer(k, c, c, pool=p, const_frac=0.1)
               for k, p in zip(ks[1:], pools[1:])]
    return engine.CutieProgram(layers, _instance(c))


@pytest.mark.parametrize("pack_boundaries", [True, False])
def test_fused_backend_cifar_like_bit_identical(pack_boundaries):
    prog = _cifar_like_program()
    x = _trits(jax.random.PRNGKey(32), (2, 32, 32, 10))
    want = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    be = FusedBackend(pack_boundaries=pack_boundaries)
    pipe = CutiePipeline(prog, backend=be)
    assert np.array_equal(np.asarray(pipe.run(x)), want)
    # the whole net — thermometer-width head included — is ONE trunk
    segs = be.plan(prog, x.shape)
    assert [(s.start, s.stop, s.fused) for s in segs] == [(0, 8, True)]


def test_fused_backend_small_budget_multi_trunk_bit_identical():
    prog = engine.CutieProgram(_uniform(8, 6, seed=33), _instance(8))
    x = _trits(jax.random.PRNGKey(34), (2, 10, 10, 8))
    want = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    budget = compiler.trunk_vmem_bytes(prog.layers[:3], x.shape) + 1
    be = FusedBackend(vmem_budget=budget)
    assert len(be.plan(prog, x.shape)) > 1
    assert np.array_equal(
        np.asarray(CutiePipeline(prog, backend=be).run(x)), want)


def test_fused_backend_traced_run_matches_ref_stats():
    """A kernel_stats tracer rides the fused program itself: per-layer
    integer counters come back from inside the megakernel, and the rows
    derived from them must be identical to the ref backend's."""
    prog = _cifar_like_program(seed=35, c=8, cin=8)
    x = _trits(jax.random.PRNGKey(36), (1, 32, 32, 8))
    y_ref, rows_ref = CutiePipeline(prog, backend="ref").run(
        x, tracer=StatsTracer())
    y, rows = CutiePipeline(prog, backend="fused").run(
        x, tracer=StatsTracer())
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert rows == rows_ref


def test_trunk_boundary_packed_io_matches_codec():
    """fused->fused boundaries: the producer's pack_out byte stream is
    exactly the reference codec's packing of its trit output, and the
    consumer's in-kernel decode reproduces the dense execution."""
    layers = [_layer(k, 8, 8, const_frac=0.2)
              for k in jax.random.split(jax.random.PRNGKey(37), 4)]
    x = _trits(jax.random.PRNGKey(38), (2, 9, 9, 8))
    a, b = layers[:2], layers[2:]

    def call(ls, x, **kw):
        return FT.fused_trunk_pallas(
            x, jnp.stack([li.weights for li in ls]),
            *_stack_thresholds(ls),
            metas=tuple((li.stride, li.pool) for li in ls),
            interpret=True, **kw)

    mid_dense = call(a, x)
    packed = call(a, x, pack_out=True)
    assert packed.dtype == jnp.uint8
    assert np.array_equal(
        np.asarray(packed),
        np.asarray(codec.pack_trits(mid_dense.reshape(-1))))
    out = call(b, packed, packed_in=tuple(mid_dense.shape))
    assert np.array_equal(_oracle(layers, x), np.asarray(out))


def test_fused_backend_respects_scan_flag_compat():
    """scan=True pipelines still work (build_program path ignores scan)."""
    prog = engine.CutieProgram(_uniform(8, 3, seed=38), _instance(8))
    x = _trits(jax.random.PRNGKey(39), (1, 8, 8, 8))
    a = np.asarray(CutiePipeline(prog, backend="fused", scan=True).run(x))
    b = np.asarray(CutiePipeline(prog, backend="fused", scan=False).run(x))
    want = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    assert np.array_equal(a, want) and np.array_equal(b, want)


def test_fused_backend_mixed_program_everything_at_once():
    """Channel growth, stride, pools, unpadded tail: segmentation +
    per-layer fallback + trunks compose bit-exactly."""
    ks = jax.random.split(jax.random.PRNGKey(41), 7)
    layers = [
        _layer(ks[0], 6, 12),
        _layer(ks[1], 12, 12, pool=("max", 2), const_frac=0.3),
        _layer(ks[2], 12, 12, stride=(2, 2)),
        _layer(ks[3], 12, 12, pool=("avg", 2)),
        _layer(ks[4], 12, 24),
        _layer(ks[5], 24, 24, padding=False),
    ]
    prog = engine.CutieProgram(layers, _instance(24))
    x = _trits(ks[6], (2, 24, 24, 6))
    want = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    got = np.asarray(CutiePipeline(prog, backend="fused").run(x))
    assert np.array_equal(want, got)


def test_trunk_dataclass_invariants():
    t = compiler.Trunk(2, 5, fused=True, vmem_bytes=10)
    assert len(t) == 3
    with pytest.raises(dataclasses.FrozenInstanceError):
        t.start = 0
