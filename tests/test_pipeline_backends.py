"""Unified pipeline API: backend equivalence, tracers, serving, shims.

The load-bearing property of `repro.pipeline`: ONE compiled CutieProgram
runs through every registered backend (`ref`, `pallas` in interpret mode,
`packed`) with bit-identical trit outputs and identical Tracer stats —
on both the scanned (uniform layer FIFO) and unrolled (mixed
stride/pool/channel) execution paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.pipeline import (CutiePipeline, StatsTracer, SwitchingTracer,
                            available_backends, get_backend, program_shapes)

BACKENDS = sorted(available_backends())


def _rand_layer(key, cin, cout, *, pool=None, stride=(1, 1), padding=True):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (3, 3, cin, cout))
    bn = {"gamma": jax.random.normal(k2, (cout,)) + 0.5,
          "beta": jnp.zeros((cout,)), "mean": jnp.zeros((cout,)),
          "var": jnp.ones((cout,))}
    return engine.compile_layer(w, bn, pool=pool, stride=stride,
                                padding=padding)


def _uniform_program(c=8, depth=3, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), depth)
    return engine.CutieProgram([_rand_layer(k, c, c) for k in keys],
                               engine.CutieInstance(n_i=c, n_o=c))


def _mixed_program(seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    layers = [
        _rand_layer(keys[0], 8, 16),
        _rand_layer(keys[1], 16, 16, pool=("max", 2)),
        _rand_layer(keys[2], 16, 8, stride=(2, 2)),
        _rand_layer(keys[3], 8, 8, pool=("avg", 2)),
    ]
    return engine.CutieProgram(layers, engine.CutieInstance(n_i=16, n_o=16))


def _trits(key, shape):
    return jax.random.randint(key, shape, -1, 2).astype(jnp.int8)


# ---------------------------------------------------------------------------
# backend equivalence (the acceptance property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("prog_kind", ["uniform", "mixed"])
def test_backend_bit_identical_and_same_stats(backend, prog_kind):
    prog = _uniform_program() if prog_kind == "uniform" else _mixed_program()
    x = _trits(jax.random.PRNGKey(42), (2, 8, 8, 8))

    ref_pipe = CutiePipeline(prog, backend="ref")
    y_ref, rows_ref = ref_pipe.run(x, tracer=StatsTracer())

    pipe = CutiePipeline(prog, backend=backend)
    y, rows = pipe.run(x, tracer=StatsTracer())

    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert set(np.unique(np.asarray(y))) <= {-1, 0, 1}
    assert rows == rows_ref
    # scan engages exactly on the uniform layer FIFO
    assert pipe.scannable == (prog_kind == "uniform")


@pytest.mark.parametrize("backend", BACKENDS)
def test_switching_tracer_identical_across_backends(backend):
    prog = _uniform_program(seed=3)
    x = _trits(jax.random.PRNGKey(7), (1, 8, 8, 8))
    _, ref_rows = CutiePipeline(prog, backend="ref").run(
        x, tracer=SwitchingTracer())
    _, rows = CutiePipeline(prog, backend=backend).run(
        x, tracer=SwitchingTracer())
    assert rows == ref_rows
    for r in ref_rows:
        assert 0.0 <= r["act_toggle"] <= 1.0
        assert 0.0 < r["weight_density"] <= 1.0
        assert r["ops"] > 0


def test_scan_matches_unrolled():
    prog = _uniform_program(seed=5)
    x = _trits(jax.random.PRNGKey(9), (2, 8, 8, 8))
    y_scan, rows_scan = CutiePipeline(prog, scan=True).run(
        x, tracer=StatsTracer())
    y_unr, rows_unr = CutiePipeline(prog, scan=False).run(
        x, tracer=StatsTracer())
    assert np.array_equal(np.asarray(y_scan), np.asarray(y_unr))
    assert rows_scan == rows_unr


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------


def test_compile_classmethod_and_shapes():
    key = jax.random.PRNGKey(0)
    c = 8
    bn = {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
          "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}
    pipe = CutiePipeline.compile(
        [(jax.random.normal(key, (3, 3, c, c)), bn),
         (jax.random.normal(key, (3, 3, c, c)), bn, {"pool": ("max", 2)})],
        instance=engine.CutieInstance(n_i=c, n_o=c))
    shapes = pipe.shapes((4, 8, 8, c))
    assert shapes == [(4, 8, 8, c), (4, 8, 8, c), (4, 4, 4, c)]
    y = pipe.run(_trits(key, (4, 8, 8, c)))
    assert y.shape == shapes[-1]
    assert program_shapes(pipe.program, (4, 8, 8, c)) == shapes


def test_get_backend_resolution():
    assert get_backend("ref").name == "ref"
    assert get_backend("pallas_interpret").name == "pallas"
    assert get_backend(get_backend("packed")).name == "packed"
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("mps")


def test_run_rejects_non_nhwc():
    pipe = CutiePipeline(_uniform_program())
    with pytest.raises(ValueError, match="N, H, W, C"):
        pipe.run(jnp.zeros((8, 8, 8), jnp.int8))


def test_measure_through_tracer_path():
    prog = _uniform_program(seed=11)
    x = _trits(jax.random.PRNGKey(1), (1, 8, 8, 8))
    en = CutiePipeline(prog).measure(x)
    assert en["avg_tops_w"] > 0
    assert len(en["layers"]) == len(prog.layers)
    assert np.array_equal(np.asarray(en["final"]),
                          np.asarray(CutiePipeline(prog).run(x)))
    # energy.model.program_energy is the same path
    from repro.energy import model as E
    en2 = E.program_energy(prog, x)
    assert en2["avg_tops_w"] == pytest.approx(en["avg_tops_w"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_engine_continuous_batching():
    prog = _uniform_program(seed=13)
    pipe = CutiePipeline(prog)
    eng = pipe.engine(buckets=(3,))

    rng = np.random.default_rng(0)
    imgs = [rng.integers(-1, 2, size=(8, 8, 8)).astype(np.int8)
            for _ in range(7)]
    uids = [eng.submit(im).uid for im in imgs]
    results = eng.run()

    assert sorted(results) == sorted(uids)
    assert eng.n_batches == 3             # ceil(7 / 3) bucketed batches
    for uid, im in zip(uids, imgs):
        want = np.asarray(pipe.run(jnp.asarray(im[None])))[0]
        assert np.array_equal(results[uid], want)

    with pytest.raises(ValueError, match="does not match serving shape"):
        eng.submit(np.zeros((4, 4, 8), np.int8))


def test_engine_tracer_covers_only_live_requests():
    """A lone request in a padded batch must not have its traced stats
    diluted by empty padding slots."""
    prog = _uniform_program(seed=23)
    pipe = CutiePipeline(prog)
    eng = pipe.engine(tracer=StatsTracer())
    img = np.asarray(_trits(jax.random.PRNGKey(3), (8, 8, 8)))
    eng.submit(img)
    eng.run()
    _, want = pipe.run(jnp.asarray(img[None]), tracer=StatsTracer())
    assert eng.traced() == [want]


def test_layer_ops_agrees_with_inferred_shape():
    """Padded strided conv on odd dims: ops must use the real (ceil)
    output extent, the one program_shapes reports."""
    from repro.pipeline import layer_out_shape

    instr = _rand_layer(jax.random.PRNGKey(29), 8, 8, stride=(2, 2))
    out_shape = layer_out_shape(instr, (1, 9, 9, 8))
    assert out_shape == (1, 5, 5, 8)
    assert engine.layer_ops(instr, (1, 9, 9, 8)) == 2 * 5 * 5 * 3 * 3 * 8 * 8


def test_engine_head_and_late_submit():
    prog = _uniform_program(seed=17)
    pipe = CutiePipeline(prog)
    eng = pipe.engine(head=lambda feats: int(feats.sum()))
    first = eng.submit(np.zeros((8, 8, 8), np.int8)).uid
    assert eng.step()
    late = eng.submit(np.ones((8, 8, 8), np.int8)).uid
    results = eng.run()
    assert set(results) == {first, late}
    assert all(isinstance(v, int) for v in results.values())


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------


def test_engine_run_program_shim_matches_pipeline():
    prog = _mixed_program(seed=19)
    x = _trits(jax.random.PRNGKey(2), (2, 8, 8, 8))
    with pytest.warns(DeprecationWarning, match="CutiePipeline"):
        y_old, stats_old = engine.run_program(prog, x, collect_stats=True)
    y_new, stats_new = CutiePipeline(prog, backend="ref").run(
        x, tracer=StatsTracer())
    assert np.array_equal(np.asarray(y_old), np.asarray(y_new))
    assert stats_old == stats_new


def test_dense_as_conv_derives_from_instance():
    w = jnp.asarray(np.random.default_rng(0).integers(
        -1, 2, size=(40, 4)), jnp.float32)
    inst = engine.CutieInstance(n_i=8, n_o=8)
    wc = engine.dense_as_conv(w, inst)
    assert wc.shape == (3, 3, 8, 4)            # k*k*n_i = 72 >= 40
    x = jnp.asarray(np.random.default_rng(1).integers(
        -1, 2, size=(40,)), jnp.int32)
    xp = jnp.pad(x, (0, 72 - 40)).reshape(1, 3, 3, 8)
    z = engine.conv2d_int(xp, wc, padding=False)
    assert np.array_equal(np.asarray(z).reshape(-1),
                          np.asarray(x @ w.astype(jnp.int32)))
    with pytest.raises(ValueError, match="exceeds OCU buffer"):
        engine.dense_as_conv(jnp.zeros((80, 4)), inst)
