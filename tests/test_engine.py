"""Bit-true CUTIE engine: compilation, execution, pooling, QAT parity."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cutie_cnn import CutieCNNConfig
from repro.core import engine
from repro.models import cutie_cnn


def _rand_layer(key, cin=8, cout=8, pool=None):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (3, 3, cin, cout))
    bn = {"gamma": jax.random.normal(k2, (cout,)) + 0.5,
          "beta": jnp.zeros((cout,)), "mean": jnp.zeros((cout,)),
          "var": jnp.ones((cout,))}
    return engine.compile_layer(w, bn, pool=pool)


def test_compile_layer_pure_trits():
    instr = _rand_layer(jax.random.PRNGKey(0))
    vals = np.unique(np.asarray(instr.weights))
    assert set(vals) <= {-1, 0, 1}
    assert instr.weights.dtype == jnp.int8


def test_program_validation():
    inst = engine.CutieInstance(n_i=8, n_o=8, n_layers=2)
    good = _rand_layer(jax.random.PRNGKey(0))
    prog = engine.CutieProgram([good, good], inst)
    prog.validate()
    with pytest.raises(ValueError, match="exceed layer FIFO"):
        engine.CutieProgram([good] * 3, inst).validate()
    big = _rand_layer(jax.random.PRNGKey(1), cin=16)
    with pytest.raises(ValueError, match="channels"):
        engine.CutieProgram([big], inst).validate()


def test_run_layer_integer_exact_vs_manual():
    key = jax.random.PRNGKey(3)
    instr = _rand_layer(key)
    x = jax.random.randint(key, (2, 8, 8, 8), -1, 2).astype(jnp.int8)
    out, z = engine.run_layer(x, instr)
    # manual conv in numpy (padding 1)
    xp = np.pad(np.asarray(x, np.int32), ((0, 0), (1, 1), (1, 1), (0, 0)))
    w = np.asarray(instr.weights, np.int32)
    zz = np.zeros((2, 8, 8, 8), np.int32)
    for i in range(8):
        for j in range(8):
            patch = xp[:, i:i + 3, j:j + 3, :]
            zz[:, i, j, :] = np.tensordot(patch, w, axes=([1, 2, 3],
                                                          [0, 1, 2]))
    assert np.array_equal(np.asarray(z), zz)
    assert set(np.unique(np.asarray(out))) <= {-1, 0, 1}


@pytest.mark.parametrize("kind", ["max", "avg"])
def test_merged_pooling_semantics(kind):
    """Engine pooling (pre-threshold) == float pipeline pool-then-quantize."""
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (3, 3, 8, 8))
    bn = {"gamma": jax.random.normal(k2, (8,)) + 0.2,
          "beta": jnp.zeros((8,)), "mean": jnp.zeros((8,)),
          "var": jnp.ones((8,))}
    instr = engine.compile_layer(w, bn, pool=(kind, 2))
    x = jax.random.randint(key, (1, 8, 8, 8), -1, 2).astype(jnp.int8)
    out, _ = engine.run_layer(x, instr)

    # float oracle: conv -> BN -> pool -> hardtanh -> ternarize
    z = engine.conv2d_int(x, instr.weights).astype(jnp.float32)
    from repro.core import ternary as T
    delta = T.twn_delta(w, axis=(0, 1, 2))
    alpha = T.twn_scale(w, T.ternarize(w, delta), axis=(0, 1, 2)).reshape(-1)
    y = bn["gamma"] * (alpha * z - bn["mean"]) / jnp.sqrt(
        bn["var"] + 1e-5) + bn["beta"]
    n, h, wd, c = y.shape
    yr = y.reshape(n, h // 2, 2, wd // 2, 2, c)
    y = (jnp.max(yr, axis=(2, 4)) if kind == "max"
         else jnp.mean(yr, axis=(2, 4)))
    y = jnp.clip(y, -1, 1)
    want = ((y > 0.5).astype(np.int8) - (y < -0.5).astype(np.int8))
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_dense_as_conv_mapping():
    w = jnp.asarray(np.random.default_rng(0).integers(
        -1, 2, size=(200, 16)), jnp.float32)
    wc = engine.dense_as_conv(w)
    assert wc.shape == (3, 3, 128, 16)
    # the conv on a one-hot "image" reproduces the dense product
    x = jnp.asarray(np.random.default_rng(1).integers(
        -1, 2, size=(200,)), jnp.int32)
    xp = jnp.pad(x, (0, 1152 - 200)).reshape(1, 3, 3, 128)
    z = engine.conv2d_int(xp, wc, padding=False)
    want = x @ w.astype(jnp.int32)
    assert np.array_equal(np.asarray(z).reshape(-1), np.asarray(want))
    with pytest.raises(ValueError):
        engine.dense_as_conv(jnp.zeros((2000, 10)))


def test_layer_ops_formula():
    instr = _rand_layer(jax.random.PRNGKey(5))
    ops = engine.layer_ops(instr, (1, 32, 32, 8))
    assert ops == 2 * 32 * 32 * 3 * 3 * 8 * 8


def test_qat_graph_vs_engine_parity():
    """Float QAT graph predictions == bit-true engine on the same params."""
    cfg = CutieCNNConfig(width=8, thermometer_m=4)
    params = cutie_cnn.init_params(cfg, jax.random.PRNGKey(0))
    from repro.core import thermometer as TH
    lv = TH.quantize_to_levels(
        jax.random.uniform(jax.random.PRNGKey(2), (4, 32, 32, 3)), 8)
    trits = TH.ternary_thermometer(lv, 4).reshape(4, 32, 32, 12)

    logits, _ = cutie_cnn.forward(params, trits.astype(jnp.float32), cfg,
                                  train=False)
    prog = cutie_cnn.to_program(params, cfg, engine.CutieInstance(
        n_i=16, n_o=16))
    from repro.pipeline import CutiePipeline
    feats = CutiePipeline(prog).run(trits.astype(jnp.int8))
    fc_w = np.asarray(cutie_cnn._quant_w(params["fc"], cfg.weight_mode))
    eng_logits = np.asarray(feats).reshape(4, -1).astype(np.float32) @ fc_w
    agree = np.mean(np.argmax(np.asarray(logits), -1)
                    == np.argmax(eng_logits, -1))
    assert agree >= 0.75      # borderline float compares may differ


def test_run_program_stats():
    from repro.pipeline import CutiePipeline, StatsTracer

    inst = engine.CutieInstance(n_i=8, n_o=8)
    layers = [_rand_layer(jax.random.PRNGKey(i)) for i in range(3)]
    prog = engine.CutieProgram(layers, inst)
    x = jax.random.randint(jax.random.PRNGKey(9), (1, 8, 8, 8), -1, 2
                           ).astype(jnp.int8)
    out, stats = CutiePipeline(prog).run(x, tracer=StatsTracer())
    assert len(stats) == 3
    for s in stats:
        assert 0 <= s["weight_sparsity"] <= 1
        assert s["ops"] > 0
