"""Pipeline-parallel layer sharding + packed-trit collectives.

Pins the PR-9 tentpole properties of `repro.launch.cutie_mesh`:

* ``"layer"`` mesh axis: trunk stages assigned one per device
  (`repro.compiler.trunks.plan_stages`), microbatched activations
  streamed through a ``ppermute`` ring — bit-identical to single-device
  ``ref`` across layer/data mesh shapes, packed and dense wire formats,
* microbatch ordering through the ring (per-sample outputs land back in
  submission order, including batches that do not divide the
  microbatch count),
* stage planning errors name the offending layer/constraint instead of
  silently running a wrong pipeline,
* serving integration: bucket rounding to the pipeline's batch quantum
  and per-stage occupancy / bubble fraction in ``engine.stats()``.

Host topology comes from ``conftest.py``'s session-wide XLA_FLAGS; the
``host_devices`` fixture skips when it could not be applied.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler import trunks
from repro.core import engine
from repro.launch.cutie_mesh import MeshSpec
from repro.pipeline import CutiePipeline
from repro.serving import CutieEngine


def _uniform_program(c, n_layers, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n_layers)
    instrs = []
    for k in keys:
        k1, k2 = jax.random.split(k)
        w = jax.random.normal(k1, (3, 3, c, c))
        bn = {"gamma": jax.random.normal(k2, (c,)) + 0.5,
              "beta": jnp.zeros((c,)), "mean": jnp.zeros((c,)),
              "var": jnp.ones((c,))}
        instrs.append(engine.compile_layer(w, bn))
    return engine.CutieProgram(instrs, engine.CutieInstance(n_i=c, n_o=c))


@pytest.fixture(scope="module")
def trunk8():
    return _uniform_program(6, 8)


@pytest.fixture(scope="module")
def trunk8_oracle(trunk8, rng):
    x = rng.integers(-1, 2, (8, 8, 8, 6)).astype(np.int8)
    y = np.asarray(CutiePipeline(trunk8, backend="ref").run(x))
    return x, y


# -- mesh spec: the layer axis ----------------------------------------------


def test_meshspec_layer_axis():
    assert MeshSpec.parse("layer:4") == MeshSpec(layer=4)
    assert MeshSpec.parse("data:2,layer:2") == MeshSpec(data=2, layer=2)
    assert MeshSpec.parse({"layer": 8}) == MeshSpec(layer=8)
    assert MeshSpec.parse((2, 1, 4)) == MeshSpec(2, 1, 4)
    assert MeshSpec(data=2, layer=4).n_devices == 8
    assert str(MeshSpec(layer=4)) == "data:1,filter:1,layer:4"
    with pytest.raises(NotImplementedError, match="do not compose"):
        MeshSpec(filter=2, layer=2)


def test_meshspec_layer_from_mesh(host_devices):
    from repro.launch import _compat

    mesh = _compat.make_mesh((2, 1, 4), ("data", "filter", "layer"))
    assert MeshSpec.parse(mesh) == MeshSpec(data=2, layer=4)


# -- stage planning ----------------------------------------------------------


def test_plan_stages(trunk8):
    stages = trunks.plan_stages(trunk8, (1, 8, 8, 6), 4)
    assert [(s.start, s.stop) for s in stages] == [
        (0, 2), (2, 4), (4, 6), (6, 8)]
    # each 2-layer stage is itself a fusible trunk on its device
    assert all(s.fused and s.vmem_bytes > 0 for s in stages)


def test_plan_stages_rejects_nondividing(trunk8):
    with pytest.raises(ValueError, match="do not split"):
        trunks.plan_stages(trunk8, (1, 8, 8, 6), 3)


def test_plan_stages_rejects_nonuniform():
    # a pooled layer changes the activation shape mid-ring
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    instrs = []
    for i, k in enumerate(keys):
        w = jax.random.normal(k, (3, 3, 6, 6))
        bn = {"gamma": jnp.ones((6,)), "beta": jnp.zeros((6,)),
              "mean": jnp.zeros((6,)), "var": jnp.ones((6,))}
        instrs.append(engine.compile_layer(
            w, bn, pool=("max", 2) if i == 1 else None))
    prog = engine.CutieProgram(instrs, engine.CutieInstance(n_i=6, n_o=6))
    with pytest.raises(ValueError, match="layer 1.*pool"):
        trunks.plan_stages(prog, (1, 8, 8, 6), 2)


# -- bit-exactness vs the single-device ref oracle ---------------------------


@pytest.mark.parametrize("spec", ["layer:2", "layer:4", "layer:8",
                                  "data:2,layer:2"])
def test_layer_sharding_bit_exact(host_devices, trunk8, trunk8_oracle,
                                  spec):
    x, y_ref = trunk8_oracle
    pipe = CutiePipeline(trunk8, backend="ref", mesh=spec)
    assert (np.asarray(pipe.run(x)) == y_ref).all()


def test_layer_sharding_dense_wire_bit_exact(host_devices, trunk8,
                                             trunk8_oracle):
    x, y_ref = trunk8_oracle
    pipe = CutiePipeline(trunk8, backend="ref", mesh="layer:4",
                         packed_collectives=False)
    assert (np.asarray(pipe.run(x)) == y_ref).all()


def test_microbatch_ordering_through_ring(host_devices, trunk8,
                                          trunk8_oracle):
    # every sample is distinct, the batch (7) does not divide the
    # microbatch count (3), and the padded tail is cropped — outputs
    # must come back in submission order, not ring-arrival order
    x, y_ref = trunk8_oracle
    pipe = CutiePipeline(trunk8, backend="ref", mesh="layer:4",
                         microbatches=3)
    y = np.asarray(pipe.run(x[:7]))
    assert y.shape == y_ref[:7].shape
    for i in range(7):
        assert (y[i] == y_ref[i]).all(), f"sample {i} misrouted"


@pytest.mark.parametrize("backend", ["pallas", "packed"])
def test_layer_sharding_kernel_backends(host_devices, trunk8,
                                        trunk8_oracle, backend):
    x, y_ref = trunk8_oracle
    pipe = CutiePipeline(trunk8, backend=backend, mesh="layer:2",
                         microbatches=2)
    assert (np.asarray(pipe.run(x[:4])) == y_ref[:4]).all()


def test_layer_sharding_rejects_nonuniform_program(host_devices, rng):
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    instrs = []
    for i, k in enumerate(keys):
        w = jax.random.normal(k, (3, 3, 6 if i == 0 else 4, 4))
        bn = {"gamma": jnp.ones((4,)), "beta": jnp.zeros((4,)),
              "mean": jnp.zeros((4,)), "var": jnp.ones((4,))}
        instrs.append(engine.compile_layer(w, bn))
    prog = engine.CutieProgram(instrs, engine.CutieInstance(n_i=6, n_o=4))
    with pytest.raises(ValueError, match="uniform trunk"):
        CutiePipeline(prog, backend="ref", mesh="layer:2")


# -- execution plan ----------------------------------------------------------


def test_execution_plan_pipeline_mode(host_devices, trunk8):
    pipe = CutiePipeline(trunk8, backend="ref", mesh="layer:4",
                         microbatches=8)
    plan = pipe.execution_plan()
    assert plan["mode"] == "sharded-pipeline"
    assert plan["collectives"] == "packed"
    assert plan["pipeline"]["stages"] == 4
    assert plan["pipeline"]["microbatches"] == 8
    assert plan["pipeline"]["bubble_fraction"] == pytest.approx(3 / 11)
    assert plan["pipeline"]["per_stage_occupancy"] == [8 / 11] * 4


def test_execution_plan_mesh_names_packed_fallback(host_devices, trunk8):
    with pytest.warns(UserWarning, match="packed"):
        pipe = CutiePipeline(trunk8, backend="fused", mesh="data:2")
    plan = pipe.execution_plan()
    assert plan["fallback"] == "mesh"
    assert plan["collectives"] == "packed"
    assert "packed" in plan["reason"]


# -- serving through a pipelined executor ------------------------------------


def test_engine_layer_sharded(host_devices, trunk8, trunk8_oracle):
    x, y_ref = trunk8_oracle
    eng = CutieEngine("fcfs")
    ex = eng.register("m", trunk8, backend="ref",
                      mesh=MeshSpec(layer=4), buckets=(1, 4))
    # buckets round to the batch quantum: data(1) * microbatches(8)
    assert ex.buckets == (8,)
    handles = [eng.submit(x[i], model="m") for i in range(5)]
    for i, h in enumerate(handles):
        assert (np.asarray(h.result()) == y_ref[i]).all()
    stats = eng.stats()
    shard = stats["sharding"]["m"]
    assert shard["layer"] == 4 and shard["devices"] == 4
    sched = shard["pipeline"]
    assert sched["stages"] == 4 and sched["microbatches"] == 8
    assert 0.0 < sched["bubble_fraction"] < 1.0
    assert len(sched["per_stage_occupancy"]) == 4
