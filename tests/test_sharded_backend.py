"""Sharded (mesh) execution is bit-identical to single-device execution.

Pins the tentpole properties of `repro.launch.cutie_mesh` +
`CutiePipeline(mesh=...)`:

* data-parallel batch sharding for batch sizes that do NOT divide the
  mesh (the padding path),
* filter-dimension (output-channel / OCU) sharding for channel counts
  that do NOT divide the device count (zero-weight / constant-zero
  threshold padding),
* all registered execution backends under a mesh,
* engine submit -> result through a meshed ProgramExecutor, including
  bucket rounding and per-device occupancy stats.

Host topology comes from ``conftest.py``'s session-wide XLA_FLAGS; the
``host_devices`` fixture skips when it could not be applied.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.launch.cutie_mesh import MeshSpec, pad_program_for_filter
from repro.pipeline import CutiePipeline
from repro.serving import CutieEngine


def _program(c_in, c, n_layers, seed=0, pools=None):
    keys = jax.random.split(jax.random.PRNGKey(seed), n_layers)
    instrs, cin = [], c_in
    for i, k in enumerate(keys):
        k1, k2 = jax.random.split(k)
        w = jax.random.normal(k1, (3, 3, cin, c))
        bn = {"gamma": jax.random.normal(k2, (c,)) + 0.5,
              "beta": jnp.zeros((c,)), "mean": jnp.zeros((c,)),
              "var": jnp.ones((c,))}
        instrs.append(engine.compile_layer(
            w, bn, pool=pools[i] if pools else None))
        cin = c
    inst = engine.CutieInstance(n_i=max(c_in, c), n_o=c)
    return engine.CutieProgram(instrs, inst)


@pytest.fixture(scope="module")
def uniform_prog():
    return _program(6, 6, 3)


@pytest.fixture(scope="module")
def uniform_oracle(uniform_prog, rng):
    x = rng.integers(-1, 2, (8, 8, 8, 6)).astype(np.int8)
    y = np.asarray(CutiePipeline(uniform_prog, backend="ref").run(x))
    return x, y


# -- mesh spec parsing (no devices needed) ----------------------------------


def test_meshspec_parse():
    assert MeshSpec.parse(4) == MeshSpec(data=4)
    assert MeshSpec.parse("data:2,filter:3") == MeshSpec(2, 3)
    assert MeshSpec.parse("filter:2") == MeshSpec(1, 2)
    assert MeshSpec.parse({"data": 2}) == MeshSpec(2, 1)
    assert MeshSpec.parse((2, 4)) == MeshSpec(2, 4)
    assert MeshSpec.parse(MeshSpec(1, 2)) == MeshSpec(1, 2)
    assert MeshSpec(2, 3).n_devices == 6
    with pytest.raises(ValueError):
        MeshSpec.parse("model:4")
    with pytest.raises(ValueError):
        MeshSpec.parse({"pipeline": 2})
    with pytest.raises(ValueError):
        MeshSpec(data=0)
    with pytest.raises(TypeError):
        MeshSpec.parse(3.5)


def test_meshspec_from_mesh(host_devices):
    from repro.launch import _compat

    mesh = _compat.make_mesh((2, 4), ("data", "filter"))
    assert MeshSpec.parse(mesh) == MeshSpec(2, 4)


def test_mesh_too_large_raises(host_devices):
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        MeshSpec(data=1024).build()


# -- filter-dimension program padding ---------------------------------------


def test_pad_program_for_filter(uniform_prog):
    layers, in_pad, final = pad_program_for_filter(uniform_prog, 4,
                                                   pad_input=True)
    assert final == 6 and in_pad == 2          # 6 -> 8 (mult of 4)
    for instr in layers:
        assert instr.weights.shape[2:] == (8, 8)
        assert instr.thresholds.t_lo.shape == (8,)
        assert bool(np.asarray(instr.thresholds.is_const)[6:].all())
        assert not np.asarray(instr.weights)[..., 6:].any()
    # without pad_input, layer 0 keeps its true input channel count
    layers, in_pad, _ = pad_program_for_filter(uniform_prog, 4)
    assert in_pad == 0 and layers[0].weights.shape[2] == 6


# -- bit-exactness vs the ref oracle ----------------------------------------


@pytest.mark.parametrize("batch", [1, 3, 5, 8])
def test_data_parallel_padding_bit_exact(host_devices, uniform_prog,
                                         uniform_oracle, batch):
    x, y_ref = uniform_oracle
    pipe = CutiePipeline(uniform_prog, backend="ref", mesh="data:4")
    y = np.asarray(pipe.run(x[:batch]))
    assert y.shape == y_ref[:batch].shape
    assert (y == y_ref[:batch]).all()


@pytest.mark.parametrize("spec", ["filter:4", "data:2,filter:2",
                                  "filter:3"])
@pytest.mark.parametrize("packed", [True, False])
def test_filter_sharding_nondividing_channels(host_devices, uniform_prog,
                                              uniform_oracle, spec, packed):
    # 6 output channels never divide 4 (or 3 evenly at every layer edge),
    # so the pack/unpack boundary sees non-multiple-of-5 shard sizes too
    x, y_ref = uniform_oracle
    pipe = CutiePipeline(uniform_prog, backend="ref", mesh=spec,
                         packed_collectives=packed)
    assert (np.asarray(pipe.run(x)) == y_ref).all()


def test_packed_collectives_cut_traffic(host_devices, uniform_prog):
    # the wire format is the one thing packed_collectives changes: same
    # bits out, ~5x fewer bytes exchanged between devices
    pipe = CutiePipeline(uniform_prog, backend="ref", mesh="filter:2")
    traffic = pipe._sharded.collective_bytes((8, 8, 8, 6))
    assert traffic["on_wire"] == traffic["packed"]
    assert 4.5 < traffic["dense"] / traffic["packed"] <= 5.0
    dense = CutiePipeline(uniform_prog, backend="ref", mesh="filter:2",
                          packed_collectives=False)
    assert dense._sharded.collective_bytes(
        (8, 8, 8, 6))["on_wire"] == traffic["dense"]


@pytest.mark.parametrize("backend", ["ref", "pallas", "packed"])
def test_all_backends_sharded(host_devices, uniform_prog, uniform_oracle,
                              backend):
    x, y_ref = uniform_oracle
    pipe = CutiePipeline(uniform_prog, backend=backend,
                         mesh="data:2,filter:2")
    assert (np.asarray(pipe.run(x[:5])) == y_ref[:5]).all()


def test_nonuniform_program_sharded(host_devices, rng):
    # pools + differing cin: unrolled (non-scan) sharded path
    prog = _program(5, 7, 3, seed=1, pools=[None, ("max", 2), ("avg", 2)])
    x = rng.integers(-1, 2, (3, 12, 12, 5)).astype(np.int8)
    y_ref = np.asarray(CutiePipeline(prog, backend="ref").run(x))
    pipe = CutiePipeline(prog, backend="ref", mesh="data:2,filter:4")
    assert not pipe.scannable
    assert (np.asarray(pipe.run(x)) == y_ref).all()


def test_scan_survives_filter_padding(host_devices, uniform_prog):
    # uniform trunk stays a lax.scan even when filter padding grows C
    pipe = CutiePipeline(uniform_prog, backend="ref", mesh="filter:4")
    assert pipe.scannable


def test_tracer_unsupported_on_mesh(host_devices, uniform_prog, rng):
    from repro.pipeline import StatsTracer

    pipe = CutiePipeline(uniform_prog, backend="ref", mesh="data:2")
    x = rng.integers(-1, 2, (2, 8, 8, 6)).astype(np.int8)
    with pytest.raises(NotImplementedError, match="tracer"):
        pipe.run(x, tracer=StatsTracer())


# -- serving through a meshed executor --------------------------------------


def test_engine_submit_result_meshed(host_devices, uniform_prog,
                                     uniform_oracle):
    x, y_ref = uniform_oracle
    eng = CutieEngine("fcfs")
    ex = eng.register("m", uniform_prog, backend="ref", mesh="data:4",
                      buckets=(1, 2, 6))
    # buckets round up to multiples of the data-parallel degree
    assert ex.buckets == (4, 8)
    handles = [eng.submit(x[i], model="m") for i in range(5)]
    for i, h in enumerate(handles):
        assert (np.asarray(h.result()) == y_ref[i]).all()
    stats = eng.stats()
    assert stats["sharding"]["m"] == {"data": 4, "filter": 1, "layer": 1,
                                      "devices": 4}
    occ = stats["per_device_occupancy"]["m"]
    assert len(occ) == 4 and occ[0] == 1.0
    # padded batches stay multiples of the data degree
    assert all(b["padded"] % 4 == 0 for b in eng.batches)


def test_engine_meshed_matches_unsharded_engine(host_devices, uniform_prog,
                                                uniform_oracle):
    x, _ = uniform_oracle
    plain = CutieEngine("fcfs")
    plain.register("m", uniform_prog, backend="ref")
    meshed = CutieEngine("fcfs")
    meshed.register("m", uniform_prog, backend="ref",
                    mesh=MeshSpec(data=2, filter=2))
    h1 = [plain.submit(x[i], model="m") for i in range(3)]
    h2 = [meshed.submit(x[i], model="m") for i in range(3)]
    for a, b in zip(h1, h2):
        assert (np.asarray(a.result()) == np.asarray(b.result())).all()
